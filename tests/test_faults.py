"""Chaos dataplane (DESIGN.md §14): deterministic fault injection, the
zero-fault bit-identity invariant, the graceful-degradation policies
(sequence-number dedup, register-bank closing, quorum-or-abort, the
consensus floor), and crash-safe run recovery.

The property tests use hypothesis when it is importable and otherwise a
deterministic seeded-enumeration shim with the same ``@given`` surface —
either way every example is reproducible in CI.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fediac import FediACConfig, aggregate_stack
from repro.core.round_plan import consensus_floor_threshold
from repro.checkpoint import load_run_state, save_run_state
from repro.netsim import (FaultConfig, NetConfig, PacketTransport,
                          SwitchDataplane, chaos_packet_dyn,
                          gilbert_elliott_stationary, make_chaos_packet_core,
                          register_accumulate)
from repro.netsim.batched import make_fediac_packet_core, packet_dyn
from repro.netsim.dataplane import DataplaneStats
from repro.netsim.faults import _ge_loss_probability
from repro.netsim.policies import INT32_MAX, INT32_MIN
from repro.training import FLConfig, FLHistory, run_federated

# ---------------------------------------------------------------------------
# property-test harness: hypothesis if available, else a deterministic shim
# ---------------------------------------------------------------------------

try:
    from hypothesis import given as _h_given
    from hypothesis import settings as _h_settings
    from hypothesis import strategies as st

    def given_examples(n_examples, **strategies):
        def deco(fn):
            return _h_settings(max_examples=n_examples, deadline=None)(
                _h_given(**strategies)(fn))
        return deco
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors the hypothesis namespace
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

    def given_examples(n_examples, **strategies):
        """Seeded enumeration standing in for hypothesis: each example's
        draws come from one fixed PRNG stream, so failures replay."""
        def deco(fn):
            def wrapper():
                rng = np.random.default_rng(0xFED1AC)
                for _ in range(n_examples):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco


# ---------------------------------------------------------------------------
# NetConfig / FaultConfig validation (the fail-fast layer)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    {"straggler_slowdown": 0.5}, {"straggler_slowdown": float("inf")},
    {"straggler_slowdown": float("nan")},
    {"vote_deadline_s": 0.0}, {"vote_deadline_s": -1.0},
    {"vote_deadline_s": float("inf")},
    {"rto_s": 0.0}, {"rto_s": -0.05}, {"rto_s": float("nan")},
    {"max_retries": 0}, {"max_retries": -3},
])
def test_netconfig_rejects_bad_timing(kw):
    with pytest.raises(ValueError):
        NetConfig(**kw)


def test_netconfig_accepts_boundary_values():
    NetConfig(straggler_slowdown=1.0, vote_deadline_s=1e-6, max_retries=1)
    NetConfig(vote_deadline_s=None)    # None = wait for everyone


@pytest.mark.parametrize("kw", [
    {"crash_rate": 1.5}, {"dup_rate": -0.1}, {"ge_loss_bad": 2.0},
    {"ge_p_gb": 0.1, "ge_p_bg": 0.0},      # absorbing bad state
    {"reorder_jitter_s": -1.0}, {"reorder_jitter_s": float("inf")},
    {"register_policy": "clamp"}, {"quorum_floor": -1},
    {"round_retries": -1}, {"backoff_s": float("nan")},
    {"rto_s": 0.0},                        # inherited validation still runs
])
def test_faultconfig_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        FaultConfig(**kw)


# ---------------------------------------------------------------------------
# register-bank policies: the int32 boundary, pinned (DESIGN.md §14)
# ---------------------------------------------------------------------------


def test_register_wrap_is_bitwise_sum_and_flags_imply_wraps():
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.integers(-2**31, 2**31, size=(13, 257),
                                    dtype=np.int64).astype(np.int32))
    summed, ovf, shift = register_accumulate(rows, policy="wrap")
    np.testing.assert_array_equal(np.asarray(summed),
                                  np.asarray(jnp.sum(rows, axis=0)))
    assert not np.any(np.asarray(shift))
    # a slot whose wrapped value differs from the exact sum must be flagged
    # (the converse can't hold: cancelling overflows still trip the sticky
    # flag)
    exact = np.asarray(rows, np.int64).sum(0)
    wrapped_wrong = exact != np.asarray(summed, np.int64)
    assert np.all(~wrapped_wrong | np.asarray(ovf))


def test_register_boundary_value_pins():
    """Regression pin at the 2^31 rail: the largest representable sum is
    exact and unflagged; one past it is flagged under every policy, and
    what lands in the register is each policy's documented answer."""
    at_max = jnp.asarray([[INT32_MAX - 1], [1]], jnp.int32)
    s, o, sh = register_accumulate(at_max)
    assert int(s[0]) == 2**31 - 1 and not bool(o[0]) and int(sh[0]) == 0

    over = jnp.asarray([[INT32_MAX], [1]], jnp.int32)
    s, o, _ = register_accumulate(over, policy="wrap")
    assert int(s[0]) == -2**31 and bool(o[0])          # the silent wrap
    s, o, _ = register_accumulate(over, policy="saturate")
    assert int(s[0]) == 2**31 - 1 and bool(o[0])
    s, o, sh = register_accumulate(over, policy="rescale")
    assert bool(o[0]) and int(sh[0]) >= 1
    # mantissa x 2^shift recovers the true sum up to the truncated low bits
    assert abs(int(s[0]) * 2**int(sh[0]) - 2**31) <= 2 * 2**int(sh[0])

    neg = jnp.asarray([[INT32_MIN], [-1]], jnp.int32)
    s, o, _ = register_accumulate(neg, policy="saturate")
    assert int(s[0]) == -2**31 and bool(o[0])


def test_register_rescale_bounds_error():
    """Every slot overflowing: the mantissa/exponent pair recovers the
    out-of-range sum to within n_rows * 2^shift (right-shift truncation),
    where saturate/wrap would be off by ~the whole magnitude."""
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(2**28, 2**30, size=(24, 96),
                                    dtype=np.int64).astype(np.int32))
    exact = np.asarray(rows, np.int64).sum(0)
    s, o, sh = register_accumulate(rows, policy="rescale")
    assert bool(np.all(np.asarray(o)))
    val = np.asarray(s, np.float64) * np.exp2(np.asarray(sh, np.float64))
    bound = rows.shape[0] * np.exp2(np.asarray(sh, np.float64))
    assert np.all(np.abs(val - exact) <= bound)
    # no overflow -> exact sum at shift 0 (the bit-identity clause)
    small = rows >> 8
    s2, o2, sh2 = register_accumulate(small, policy="rescale")
    assert not bool(np.any(o2)) and not bool(np.any(sh2))
    np.testing.assert_array_equal(np.asarray(s2, np.int64),
                                  np.asarray(small, np.int64).sum(0))


def test_register_rescale_windows_degrade_together():
    """One exponent per register window: a hot window's slots all take the
    window max shift; a clean window keeps exact sums at shift 0."""
    hot = np.full((8, 4), 2**29, np.int32)
    cold = np.ones((8, 4), np.int32)
    rows = jnp.asarray(np.concatenate([hot, cold], axis=1))
    win = np.array([0] * 4 + [1] * 4, np.int32)
    s, o, sh = register_accumulate(rows, policy="rescale",
                                   slot_window=win, n_windows=2)
    sh = np.asarray(sh)
    assert len(set(sh[:4].tolist())) == 1 and sh[0] >= 1
    assert np.all(sh[4:] == 0)
    np.testing.assert_array_equal(np.asarray(s)[4:], 8)


def test_switch_dataplane_overflow_audit():
    """The host-path register bank audits each window against an exact
    int64 sum and counts silently-wrapped registers (satellite of §14)."""
    dp = SwitchDataplane(memory_slots=8)
    bufs = np.zeros((2, 8), np.int32)
    bufs[0, 3] = 2**31 - 1
    bufs[1, 3] = 1                     # slot 3 wraps
    bufs[0, 5] = 2**31 - 2
    bufs[1, 5] = 1                     # slot 5 lands exactly on the rail
    out = dp.aggregate_windowed(bufs)
    assert dp.stats.overflow_slots == 1
    assert out[5] == 2**31 - 1
    assert out[3] == -2**31            # hardware wrap, recorded not hidden
    merged = dp.stats.merge(DataplaneStats(overflow_slots=2))
    assert merged.overflow_slots == 3


# ---------------------------------------------------------------------------
# consensus floor: dense-mask fallback when the consensus set collapses
# ---------------------------------------------------------------------------


def test_consensus_floor_threshold_values():
    counts = jnp.asarray([5, 2, 2, 1, 0], jnp.int32)
    # live(a=3) == 1 < floor 4: collapse to a=1 (every voted chunk)
    assert int(consensus_floor_threshold(counts, 3, 4)) == 1
    # live(a=2) == 3 >= floor 3: threshold untouched
    assert int(consensus_floor_threshold(counts, 2, 3)) == 2
    assert int(consensus_floor_threshold(counts, 3, 1)) == 3


def test_consensus_floor_dense_fallback_in_aggregate():
    """An over-strict vote threshold starves the consensus set; the floor
    falls back toward the dense mask instead of shipping a near-empty
    round.  floor=0 (the default) leaves the plan bitwise untouched."""
    u = jax.random.normal(jax.random.PRNGKey(0), (6, 512)) ** 3
    key = jax.random.PRNGKey(1)
    base = aggregate_stack(u, FediACConfig(a=6), key)
    zero = aggregate_stack(u, FediACConfig(a=6, consensus_floor=0), key)
    assert bool(jnp.all(base[0] == zero[0]))
    floored = aggregate_stack(
        u, FediACConfig(a=6, consensus_floor=256), key)
    nnz_base = int(jnp.sum(base[0] != 0.0))
    nnz_floor = int(jnp.sum(floored[0] != 0.0))
    assert nnz_floor > nnz_base


# ---------------------------------------------------------------------------
# the zero-fault invariant: chaos core == plain core, bitwise
# ---------------------------------------------------------------------------

_N, _D = 8, 600


def _probe_inputs():
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.standard_normal((_N, _D)), jnp.float32)
    rates = jnp.full((_N,), 12.5e6, jnp.float32)
    return u, rates


@pytest.mark.parametrize("policy", ["wrap", "saturate", "rescale"])
def test_chaos_core_faultfree_bit_identical_to_plain(policy):
    """With every fault knob at its zero default the chaos core returns
    the plain core's delta, residuals and every aux entry bitwise — under
    loss, partial participation, stragglers and a deadline — for all
    three register policies (clean rounds never reach the degraded
    paths)."""
    cfg = FediACConfig(bits=12, a=3, alpha=0.1)
    netkw = dict(loss=0.15, participation=0.8, straggler_frac=0.25,
                 vote_deadline_s=1.5, seed=3)
    plain_net = NetConfig(**netkw)
    fault_net = FaultConfig(**netkw, register_policy=policy)
    pcore = make_fediac_packet_core(cfg, plain_net, _N)
    ccore = make_chaos_packet_core(cfg, fault_net, _N)
    pd = packet_dyn(cfg, plain_net, _N, 1.0, 1e-5)
    cd = chaos_packet_dyn(cfg, fault_net, _N, 1.0, 1e-5)
    u, rates = _probe_inputs()
    nk = jax.random.PRNGKey(plain_net.seed)
    for t in range(2):
        key = jax.random.fold_in(jax.random.PRNGKey(9), t)
        d1, r1, a1 = pcore(u, key, nk, t, rates, pd)
        d2, r2, a2 = ccore(u, key, nk, t, rates, cd)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        for k in a1:
            np.testing.assert_array_equal(np.asarray(a1[k]),
                                          np.asarray(a2[k]), err_msg=k)
        u = u * 0.9 + d1[None, :] + r1


def test_chaos_transport_faultfree_matches_plain():
    """The PacketTransport dispatch: a zero-rate FaultConfig rides the
    chaos core yet reproduces the plain round, and surfaces the chaos
    stats (all zero on a clean round)."""
    cfg = FediACConfig(a=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (8, 2048)) ** 3
    key = jax.random.PRNGKey(0)
    netkw = dict(loss=0.1, participation=0.75, seed=2)
    rp = PacketTransport("fediac", {"cfg": cfg},
                         net=NetConfig(**netkw)).round(u, None, key, 1)
    rc = PacketTransport("fediac", {"cfg": cfg},
                         net=FaultConfig(**netkw)).round(u, None, key, 1)
    assert bool(jnp.all(rp.delta == rc.delta))
    assert bool(jnp.all(rp.residuals == rc.residuals))
    assert rp.wall_clock_s == rc.wall_clock_s
    assert rp.upload_bytes == rc.upload_bytes
    for k in ("crashed", "duplicates", "resets", "overflow_slots",
              "aborted"):
        assert rc.stats[k] == 0, k
    assert rc.stats["attempts"] == 1


def test_fl_chaos_faultfree_matches_plain_packet(small_fl):
    """FL-level acceptance: a fault-free chaos configuration's training
    run is bit-identical to sequential run_federated over the plain
    packet transport."""
    clients, test = small_fl
    kw = dict(n_clients=6, rounds=3, local_steps=2, aggregator="fediac",
              agg_kwargs={"cfg": FediACConfig(a=2, bits=12)}, seed=0,
              transport="packet")
    h_plain = run_federated(clients, test,
                            FLConfig(net=NetConfig(loss=0.02, seed=1), **kw))
    h_chaos = run_federated(clients, test,
                            FLConfig(net=FaultConfig(loss=0.02, seed=1),
                                     **kw))
    assert h_plain.acc == h_chaos.acc
    assert h_plain.loss == h_chaos.loss
    assert h_plain.wall_clock == h_chaos.wall_clock
    assert h_plain.traffic_mb == h_chaos.traffic_mb


def test_chaos_cells_batch_on_fleet_axis():
    """Fault scenarios ride the fleet: the chaos grid's cells share one
    batch signature (rates are dynamic), and each batched cell's history
    equals its sequential run_federated history exactly."""
    from dataclasses import replace

    from repro.sweep import run_cell_sequential, run_sweep
    from repro.sweep.grids import chaos_grid

    specs = [replace(s, rounds=3) for s in chaos_grid()[:3]]
    assert len({s.batch_signature() for s in specs}) == 1
    fleet = {c.spec.name: c.history for c in run_sweep(specs, (0,))}
    for s in specs:
        seq = run_cell_sequential(s, 0)
        h = fleet[s.name]
        assert h.acc == seq.acc, s.name
        assert h.loss == seq.loss, s.name
        assert h.wall_clock == seq.wall_clock, s.name
        assert h.traffic_mb == seq.traffic_mb, s.name


# ---------------------------------------------------------------------------
# fault models and degradation policies
# ---------------------------------------------------------------------------

_DUP = None


def _dup_harness():
    """One jitted chaos core reused across property examples — dup_rate is
    dynamic, so every example is a cache hit on the same program."""
    global _DUP
    if _DUP is None:
        cfg = FediACConfig(a=3)
        net = FaultConfig(loss=0.05, participation=0.9, seed=5)
        core = jax.jit(make_chaos_packet_core(cfg, net, _N))
        dyn0 = chaos_packet_dyn(cfg, net, _N, 1.0, 1e-5)
        u, rates = _probe_inputs()
        _DUP = (core, dyn0, u, rates)
    return _DUP


@given_examples(6, rate=st.floats(min_value=0.1, max_value=0.9),
                round_idx=st.integers(min_value=0, max_value=40))
def test_duplicate_delivery_idempotent(rate, round_idx):
    """Property (ACK-loss dedup): k-fold duplicate delivery equals single
    delivery — under sequence-number suppression the committed aggregate,
    residuals and vote counts are bitwise invariant to any duplication
    rate; only the time/byte accounting moves."""
    core, dyn0, u, rates = _dup_harness()
    key, nk = jax.random.PRNGKey(7), jax.random.PRNGKey(5)
    d0, r0, a0 = core(u, key, nk, round_idx, rates, dyn0)
    dyn = dict(dyn0, dup_rate=jnp.float32(rate))
    d1, r1, a1 = core(u, key, nk, round_idx, rates, dyn)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
    np.testing.assert_array_equal(np.asarray(a0["counts"]),
                                  np.asarray(a1["counts"]))
    assert int(a1["duplicates"]) > 0
    assert int(a1["retransmissions"]) >= int(a0["retransmissions"])


def test_no_dedup_admits_double_counts():
    """Without duplicate suppression a duplicated packet's slots deposit
    twice — the corruption the sequence-number policy exists to stop."""
    cfg = FediACConfig(a=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (8, 2048)) ** 3
    key = jax.random.PRNGKey(0)
    netkw = dict(dup_rate=0.9, seed=6)
    r_dd = PacketTransport("fediac", {"cfg": cfg},
                           net=FaultConfig(dedup=True, **netkw)).round(
        u, None, key, 0)
    r_nd = PacketTransport("fediac", {"cfg": cfg},
                           net=FaultConfig(dedup=False, **netkw)).round(
        u, None, key, 0)
    assert r_nd.stats["duplicates"] > 0
    assert not bool(jnp.all(r_dd.delta == r_nd.delta))


@given_examples(6, p_gb=st.floats(min_value=0.02, max_value=0.3),
                p_bg=st.floats(min_value=0.1, max_value=0.6))
def test_ge_marginal_matches_stationary(p_gb, p_bg):
    """Property (bursty loss): the empirical bad-state occupancy of the
    Gilbert–Elliott chain matches the stationary distribution
    p_gb / (p_gb + p_bg) once past burn-in."""
    n_pkts = 4000
    probs = np.asarray(_ge_loss_probability(
        jax.random.PRNGKey(11), (16, n_pkts), 0.05, p_gb, p_bg, 0.9))
    bad = probs == np.float32(0.9)
    pi = gilbert_elliott_stationary(p_gb, p_bg)
    emp = bad[:, n_pkts // 4:].mean()        # chain starts good: burn-in
    assert abs(emp - pi) < 0.04


def test_ge_zero_rate_is_iid_loss():
    probs = np.asarray(_ge_loss_probability(
        jax.random.PRNGKey(2), (8, 100), 0.07, 0.0, 0.5, 1.0))
    assert np.all(probs == np.float32(0.07))
    assert gilbert_elliott_stationary(0.0, 0.5) == 0.0
    assert gilbert_elliott_stationary(0.1, 0.3) == pytest.approx(0.25)


def test_crash_all_phase2_commits_nothing():
    """All-or-nothing commit: every client crashing mid-upload leaves a
    zero delta and full residual carry-over — never a partial aggregate."""
    cfg = FediACConfig(a=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (8, 2048)) ** 3
    net = FaultConfig(crash_rate=1.0, crash_p2_frac=1.0, seed=0)
    r = PacketTransport("fediac", {"cfg": cfg}, net=net).round(
        u, None, jax.random.PRNGKey(0), 0)
    assert r.n_active == 0
    assert bool(jnp.all(r.delta == 0.0))
    assert bool(jnp.all(r.residuals == u))
    assert r.stats["crashed"] == u.shape[0]


def test_quorum_abort_and_retry_backoff():
    """Quorum-or-abort: an unreachable floor exhausts every retry and
    aborts (zero delta, time still spent, extra attempts burn more
    simulated clock); a reachable floor closes on the first attempt."""
    cfg = FediACConfig(a=2)
    u = jax.random.normal(jax.random.PRNGKey(1), (8, 2048)) ** 3
    key = jax.random.PRNGKey(0)
    n = u.shape[0]
    mk = lambda **kw: PacketTransport(          # noqa: E731
        "fediac", {"cfg": cfg}, net=FaultConfig(seed=1, **kw))
    r = mk(quorum_floor=n + 1, round_retries=2, backoff_s=0.2).round(
        u, None, key, 0)
    assert r.stats["aborted"] == 1
    assert r.stats["attempts"] == 3
    assert bool(jnp.all(r.delta == 0.0))
    assert bool(jnp.all(r.residuals == u))
    r0 = mk(quorum_floor=n + 1, round_retries=0, backoff_s=0.2).round(
        u, None, key, 0)
    assert r0.stats["attempts"] == 1
    assert r.wall_clock_s > r0.wall_clock_s
    ok = mk(quorum_floor=1, round_retries=2).round(u, None, key, 0)
    assert ok.stats["aborted"] == 0 and ok.stats["attempts"] == 1
    assert ok.n_active >= 1


# ---------------------------------------------------------------------------
# crash-safe recovery: round checkpoints and bit-exact resume
# ---------------------------------------------------------------------------


def test_run_state_roundtrip(tmp_path):
    path = str(tmp_path / "state.npz")
    flat = np.linspace(-1, 1, 37, dtype=np.float32)
    e = (np.arange(12, dtype=np.float32) / 7).reshape(3, 4)
    key = np.asarray(jax.random.PRNGKey(5))
    hist = FLHistory(acc=[0.1, 0.2], wall_clock=[1.5, 3.25],
                     traffic_mb=[0.5, 1.0], loss=[2.0, 1.5])
    save_run_state(path, flat=flat, e_stack=e, key=key, agg_state=None,
                   round_idx=2, t_cum=3.25, mb_cum=1.0, history=hist)
    st_ = load_run_state(path)
    np.testing.assert_array_equal(st_["flat"], flat)
    np.testing.assert_array_equal(st_["e_stack"], e)
    np.testing.assert_array_equal(st_["key"], key)
    assert st_["agg_state"] is None
    assert st_["round"] == 2
    assert st_["t_cum"] == 3.25 and st_["mb_cum"] == 1.0
    assert st_["history"]["acc"] == [0.1, 0.2]
    assert st_["history"]["wall_clock"] == [1.5, 3.25]
    assert st_["history"]["loss"] == [2.0, 1.5]
    # atomic write: no .tmp left behind
    assert not os.path.exists(path + ".tmp")


@pytest.fixture(scope="module")
def small_fl():
    from repro.data import classification, partition_dirichlet
    data = classification(n=1500, dim=16, n_classes=10, seed=0)
    train, test = data.test_split(0.25)
    return partition_dirichlet(train, 6, beta=0.5, seed=0), test


_RESUME = None


def _resume_harness():
    """Shared data + the uninterrupted reference run for the kill/resume
    property (module-global: the shim's property wrapper takes no pytest
    fixtures)."""
    global _RESUME
    if _RESUME is None:
        from repro.data import classification, partition_dirichlet
        data = classification(n=1500, dim=16, n_classes=10, seed=0)
        train, test = data.test_split(0.25)
        clients = partition_dirichlet(train, 6, beta=0.5, seed=0)
        full = _resume_run(clients, test, 6)
        _RESUME = (clients, test, full)
    return _RESUME


def _resume_run(clients, test, rounds, ckpt=None, resume=False, net=None):
    kw = dict(n_clients=6, rounds=rounds, local_steps=2,
              aggregator="fediac",
              agg_kwargs={"cfg": FediACConfig(a=2, bits=12)}, seed=0,
              ckpt_path=ckpt, resume=resume)
    if net is not None:
        kw.update(transport="packet", net=net)
    return run_federated(clients, test, FLConfig(**kw))


@given_examples(3, k=st.integers(min_value=1, max_value=5))
def test_kill_at_any_round_resume_bit_identical(k):
    """Property (crash-safe recovery): training to round k, dying, and
    resuming from the checkpoint reproduces the uninterrupted run's
    FLHistory bit-exactly — for any kill round."""
    clients, test, full = _resume_harness()
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, f"kill{k}.npz")
        _resume_run(clients, test, k, ckpt=ck)          # the "killed" run
        resumed = _resume_run(clients, test, 6, ckpt=ck, resume=True)
    assert resumed.acc == full.acc
    assert resumed.loss == full.loss
    assert resumed.wall_clock == full.wall_clock
    assert resumed.traffic_mb == full.traffic_mb


def test_checkpointing_never_perturbs_the_run():
    """Writing round checkpoints is observation, not interference: the
    checkpointed run's history equals the plain run's bitwise."""
    clients, test, full = _resume_harness()
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "observer.npz")
        h = _resume_run(clients, test, 6, ckpt=ck)
        st_ = load_run_state(ck)
    assert h.acc == full.acc and h.wall_clock == full.wall_clock
    assert st_["round"] == 6
    assert st_["history"]["acc"] == full.acc


def test_resume_under_chaos_bit_identical(small_fl, tmp_path):
    """Recovery composes with fault injection: fault draws are a pure
    function of (seed, round), so a resumed chaotic run replays the same
    faults and lands on the uninterrupted history exactly."""
    clients, test = small_fl
    net = FaultConfig(loss=0.05, crash_rate=0.15, dup_rate=0.2,
                      ge_p_gb=0.05, participation=0.9, seed=4)
    full = _resume_run(clients, test, 4, net=net)
    ck = str(tmp_path / "chaos.npz")
    _resume_run(clients, test, 2, ckpt=ck, net=net)
    resumed = _resume_run(clients, test, 4, ckpt=ck, resume=True, net=net)
    assert resumed.acc == full.acc
    assert resumed.loss == full.loss
    assert resumed.wall_clock == full.wall_clock
    assert resumed.traffic_mb == full.traffic_mb
