"""Byzantine-robust voting and aggregation (DESIGN.md §18): the
zero-adversary bit-identity invariant, the trimmed/median order-statistic
close, switch-side defenses answering each attack family, and the
reputation/quarantine state machine riding the checkpoint path.

Property tests reuse the hypothesis-or-seeded-enumeration shim from
``test_faults`` so every example replays deterministically in CI.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_run_state
from repro.core import engines
from repro.core.fediac import (FediACConfig, aggregate_round,
                               aggregate_stack)
from repro.core.robust_agg import trim_count, trimmed_sum
from repro.netsim import (FaultConfig, NetConfig, PacketTransport,
                          chaos_packet_dyn, make_chaos_packet_core)
from repro.robust import (ROBUST_STAT_FIELDS, AdversaryConfig,
                          adversary_packet_dyn, init_reputation_state,
                          make_robust_packet_core, reputation_update)
from repro.training import FLConfig, run_federated
from test_faults import given_examples, st

MODES = [("topk", "topk"), ("topk", "block"),
         ("threshold", "topk"), ("threshold", "block")]

_N, _D = 8, 600


def _probe_inputs():
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.standard_normal((_N, _D)), jnp.float32)
    rates = jnp.full((_N,), 12.5e6, jnp.float32)
    return u, rates


def _run_rounds(cfg, net, rounds=1, u=None):
    """Drive the robust core for ``rounds``, threading the reputation
    carry; returns the per-round ``(delta, res, aux, state_in)`` list and
    the final state."""
    core = make_robust_packet_core(cfg, net, _N)
    dyn = adversary_packet_dyn(cfg, net, _N, 1.0, 1e-5)
    u0, rates = _probe_inputs()
    if u is not None:
        u0 = u
    state = init_reputation_state(_N)
    nk = jax.random.PRNGKey(net.seed)
    out, uu = [], u0
    for t in range(rounds):
        key = jax.random.fold_in(jax.random.PRNGKey(9), t)
        d, r, a, state_next = core(uu, state, key, nk, t, rates, dyn)
        out.append((d, r, a, state))
        state = state_next
        uu = uu * 0.9 + d[None, :] + r
    return out, state


@pytest.fixture
def u_stack():
    return jax.random.normal(jax.random.PRNGKey(1), (8, 2048)) ** 3


@pytest.fixture(scope="module")
def small_fl():
    from repro.data import classification, partition_dirichlet
    data = classification(n=1500, dim=16, n_classes=10, seed=0)
    train, test = data.test_split(0.25)
    return partition_dirichlet(train, 6, beta=0.5, seed=0), test


# ---------------------------------------------------------------------------
# the zero-adversary invariant: robust core == chaos core, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("vote_mode,compact_mode", MODES)
def test_robust_core_zero_adversary_bit_identical_to_chaos(vote_mode,
                                                           compact_mode):
    """With every adversary/defense knob at its zero default the robust
    core returns the chaos core's delta, residuals and every aux entry
    bitwise — with the §14 faults *active* (loss, crashes, duplicates),
    for all four vote x compact mode pairs — and every robust stat is
    zero."""
    cfg = FediACConfig(bits=12, a=3, alpha=0.1, vote_mode=vote_mode,
                       compact_mode=compact_mode)
    netkw = dict(loss=0.05, participation=0.9, crash_rate=0.1,
                 dup_rate=0.1, seed=3)
    ccore = make_chaos_packet_core(cfg, FaultConfig(**netkw), _N)
    rcore = make_robust_packet_core(cfg, AdversaryConfig(**netkw), _N)
    cd = chaos_packet_dyn(cfg, FaultConfig(**netkw), _N, 1.0, 1e-5)
    rd = adversary_packet_dyn(cfg, AdversaryConfig(**netkw), _N, 1.0, 1e-5)
    u, rates = _probe_inputs()
    state = init_reputation_state(_N)
    nk = jax.random.PRNGKey(3)
    for t in range(2):
        key = jax.random.fold_in(jax.random.PRNGKey(9), t)
        d1, r1, a1 = ccore(u, key, nk, t, rates, cd)
        d2, r2, a2, state = rcore(u, state, key, nk, t, rates, rd)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        for k in a1:
            np.testing.assert_array_equal(np.asarray(a1[k]),
                                          np.asarray(a2[k]), err_msg=k)
        for k in ("byzantine", "stuffed_votes", "budget_rejected",
                  "clipped_values", "trimmed_values", "quarantined",
                  "rep_flagged"):
            assert int(a2[k]) == 0, k
        u = u * 0.9 + d1[None, :] + r1
    # the carry stays at its init: no suspicion without a signal source
    assert not bool(jnp.any(state["quarantine"] > 0))


@pytest.mark.parametrize("vote_mode,compact_mode", MODES)
def test_robust_transport_lossless_matches_aggregate_stack(u_stack,
                                                           vote_mode,
                                                           compact_mode):
    """The §9 core guarantee survives the robust dispatch: a zero-knob
    AdversaryConfig under lossless full participation reproduces
    ``aggregate_stack`` bitwise — delta, residuals and vote counts."""
    cfg = FediACConfig(vote_mode=vote_mode, compact_mode=compact_mode, a=2)
    key = jax.random.PRNGKey(42)
    delta0, res0, counts0, traffic0 = aggregate_stack(u_stack, cfg, key)
    tp = PacketTransport("fediac", {"cfg": cfg}, net=AdversaryConfig())
    r = tp.round(u_stack, None, key, round_idx=0)
    assert bool(jnp.all(delta0 == r.delta))
    assert bool(jnp.all(res0 == r.residuals))
    np.testing.assert_array_equal(np.asarray(counts0),
                                  r.stats["vote_counts"])
    assert r.traffic == traffic0
    # the reputation carry rides RoundResult.state, starting cold
    assert r.state is not None
    assert not bool(jnp.any(r.state["quarantine"] > 0))


def test_robust_transport_zero_knob_matches_plain(u_stack):
    """The PacketTransport dispatch: a zero-knob AdversaryConfig rides
    the robust core yet reproduces the plain round under loss and partial
    participation, and surfaces the robust stats (all zero)."""
    cfg = FediACConfig(a=2)
    key = jax.random.PRNGKey(0)
    netkw = dict(loss=0.1, participation=0.75, seed=2)
    rp = PacketTransport("fediac", {"cfg": cfg},
                         net=NetConfig(**netkw)).round(u_stack, None, key, 1)
    rr = PacketTransport("fediac", {"cfg": cfg},
                         net=AdversaryConfig(**netkw)).round(
        u_stack, None, key, 1)
    assert bool(jnp.all(rp.delta == rr.delta))
    assert bool(jnp.all(rp.residuals == rr.residuals))
    assert rp.wall_clock_s == rr.wall_clock_s
    assert rp.upload_bytes == rr.upload_bytes
    for k in ROBUST_STAT_FIELDS:
        assert rr.stats[k] == 0, k


def test_fl_robust_zero_knob_matches_plain_packet(small_fl):
    """FL-level acceptance: an attack-free AdversaryConfig training run
    is bit-identical to run_federated over the plain packet transport."""
    clients, test = small_fl
    kw = dict(n_clients=6, rounds=3, local_steps=2, aggregator="fediac",
              agg_kwargs={"cfg": FediACConfig(a=2, bits=12)}, seed=0,
              transport="packet")
    h_plain = run_federated(clients, test,
                            FLConfig(net=NetConfig(loss=0.02, seed=1), **kw))
    h_rob = run_federated(clients, test,
                          FLConfig(net=AdversaryConfig(loss=0.02, seed=1),
                                   **kw))
    assert h_plain.acc == h_rob.acc
    assert h_plain.loss == h_rob.loss
    assert h_plain.wall_clock == h_rob.wall_clock
    assert h_plain.traffic_mb == h_rob.traffic_mb


def test_attack_cells_batch_on_fleet_axis():
    """Attack x defense scenarios ride the fleet: every attack_grid cell
    shares one batch signature (all adversary knobs are dynamic, the trim
    close is pinned structurally), and each batched cell's history equals
    its sequential run_federated history exactly."""
    from dataclasses import replace

    from repro.sweep import run_cell_sequential, run_sweep
    from repro.sweep.grids import attack_grid

    grid = attack_grid()
    assert len({s.batch_signature() for s in grid}) == 1
    specs = [replace(grid[i], rounds=3) for i in (0, 3, 4)]
    fleet = {c.spec.name: c.history for c in run_sweep(specs, (0,))}
    for s in specs:
        seq = run_cell_sequential(s, 0)
        h = fleet[s.name]
        assert h.acc == seq.acc, s.name
        assert h.loss == seq.loss, s.name
        assert h.wall_clock == seq.wall_clock, s.name
        assert h.traffic_mb == seq.traffic_mb, s.name


# ---------------------------------------------------------------------------
# the order-statistic close: trim / median semantics
# ---------------------------------------------------------------------------


@given_examples(6, seed=st.integers(min_value=0, max_value=1000),
                n_live=st.integers(min_value=1, max_value=8))
def test_trim_zero_is_masked_sum_bitwise(seed, n_live):
    """Property: at ``t == 0`` the order-statistic close keeps exactly
    the live rows — the kept sum equals the plain masked sum bitwise for
    any live mask (the attack-grid control cells rely on this)."""
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.integers(-2**20, 2**20, size=(8, 33)), jnp.int32)
    live = jnp.asarray(rng.permutation(np.arange(8)) < n_live)
    s, kept = trimmed_sum(v, live, 0)
    np.testing.assert_array_equal(
        np.asarray(s),
        np.asarray(jnp.sum(jnp.where(live[:, None], v, 0), axis=0)))
    assert int(kept) == n_live


@given_examples(8, seed=st.integers(min_value=0, max_value=1000),
                f=st.integers(min_value=1, max_value=3))
def test_trimmed_mean_bounded_by_honest_range(seed, f):
    """Property (the §18 guarantee): with at most ``f`` adversarial
    values per slot and trim depth ``t >= f``, the kept mean of every
    slot lies within the honest values' range — no matter how extreme
    the poisoned values are."""
    n, c = 10, 17
    rng = np.random.default_rng(seed)
    honest = rng.integers(-1000, 1000, size=(n, c))
    v = honest.copy()
    bad = rng.choice(n, size=f, replace=False)
    v[bad] = rng.choice([-2**28, 2**28 - 1], size=(f, c))
    live = jnp.ones((n,), bool)
    s, kept = trimmed_sum(jnp.asarray(v, jnp.int32), live, f)
    mean = np.asarray(s, np.float64) / int(kept)
    good = np.ones(n, bool)
    good[bad] = False
    lo = honest[good].min(axis=0)
    hi = honest[good].max(axis=0)
    assert np.all(mean >= lo) and np.all(mean <= hi)


def test_median_close_exact_values():
    """Median = maximal trim: the middle value for odd ``n_live``, the
    two middle values' sum for even — pinned on exact small inputs."""
    live5 = jnp.ones((5,), bool)
    v5 = jnp.asarray([[5], [1], [9], [3], [7]], jnp.int32)
    t5 = trim_count("median", 0.0, 5)
    assert int(t5) == 2
    s, kept = trimmed_sum(v5, live5, t5)
    assert int(s[0]) == 5 and int(kept) == 1
    v4 = jnp.asarray([[4], [1], [10], [7]], jnp.int32)
    live4 = jnp.ones((4,), bool)
    t4 = trim_count("median", 0.0, 4)
    assert int(t4) == 1
    s, kept = trimmed_sum(v4, live4, t4)
    assert int(s[0]) == 11 and int(kept) == 2


def test_trim_dead_rows_and_tie_break():
    """Dead (non-committed) rows carry the dtype-max sentinel: they sort
    strictly after every live value and never reach the kept sum, however
    extreme their payload.  Equal live values break ties by client index
    (stable argsort), so the close is deterministic."""
    v = jnp.asarray([[2**31 - 1], [3], [5]], jnp.int32)
    live = jnp.asarray([False, True, True])
    s, kept = trimmed_sum(v, live, 0)
    assert int(s[0]) == 8 and int(kept) == 2
    # all-equal values, n=4, t=1: the stable rank keeps rows 1 and 2
    veq = jnp.full((4, 1), 7, jnp.int32)
    s, kept = trimmed_sum(veq, jnp.ones((4,), bool), 1)
    assert int(s[0]) == 14 and int(kept) == 2
    # trim_count clamps so at least one value survives per slot
    assert int(trim_count("trim", 0.49, 2)) == 0
    assert int(trim_count("trim", 0.9, 9)) == 4
    assert int(trim_count("median", 0.0, 1)) == 0


def test_aggregate_stack_trim_zero_identical_to_sum(u_stack):
    """``robust_agg="trim"`` at ``trim_frac=0`` is value-identical to the
    plain sum close through the full in-memory aggregation — every mode
    pair, bitwise."""
    key = jax.random.PRNGKey(7)
    for vm, cm in MODES:
        base = dict(vote_mode=vm, compact_mode=cm, a=2, bits=12)
        ref = aggregate_stack(u_stack, FediACConfig(**base), key)
        got = aggregate_stack(
            u_stack, FediACConfig(robust_agg="trim", trim_frac=0.0, **base),
            key)
        for r, g in zip(ref[:3], got[:3]):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
        assert ref[3] == got[3]


def test_engines_agree_under_robust_agg():
    """Every registered engine (monolithic, stream, sharded) reproduces
    the oracle bitwise under the trim and median closes — the client_sum
    seam holds across the engine matrix."""
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(5, 144)).astype(np.float32))
    key = jax.random.PRNGKey(13)
    for mode, tf in (("trim", 0.25), ("median", 0.0)):
        base = FediACConfig(k_frac=0.2, capacity_frac=0.25, bits=5,
                            robust_agg=mode, trim_frac=tf)
        ref = aggregate_stack(u, base, key)
        for name in engines.names():
            cfg = FediACConfig(**{**base.__dict__,
                                  "engine": engines.get(name)})
            got = aggregate_round(u, cfg, key)
            for r, g in zip(ref[:3], got[:3]):
                r, g = np.asarray(r), np.asarray(g)
                assert r.shape == g.shape and np.array_equal(
                    r.view(np.uint8), g.view(np.uint8)), (name, mode)
            assert ref[3] == got[3], (name, mode)


# ---------------------------------------------------------------------------
# attacks move the round; the switch-side defenses answer
# ---------------------------------------------------------------------------

_CLEAN_KW = dict(loss=0.0, participation=1.0, seed=0)


def test_attacks_perturb_the_round():
    """Each attack family is live: Byzantine rounds report the cohort,
    stuffed ballots and a delta that differs from the clean round's."""
    cfg = FediACConfig(a=2, bits=12)
    (clean,), _ = _run_rounds(cfg, AdversaryConfig(**_CLEAN_KW))
    (att,), _ = _run_rounds(cfg, AdversaryConfig(
        byzantine_frac=0.5, vote_stuff_frac=0.5, poison_scale=-4.0,
        **_CLEAN_KW))
    assert int(att[2]["byzantine"]) > 0
    assert int(att[2]["stuffed_votes"]) > 0
    assert int(np.sum(np.asarray(att[2]["byzantine_mask"]))) > 0
    assert not bool(jnp.all(clean[0] == att[0]))


def test_vote_budget_suppresses_stuffing():
    """The per-client vote budget rejects ballots past the cap: stuffed
    vote counts move back toward the clean round's GIA counts, and the
    rejections are counted."""
    cfg = FediACConfig(a=2, bits=12)
    n_chunks = _D // cfg.vote_chunk
    budget = int(np.ceil(cfg.k_frac * n_chunks)) + 1
    attack = dict(byzantine_frac=0.5, vote_stuff_frac=0.9, **_CLEAN_KW)
    (clean,), _ = _run_rounds(cfg, AdversaryConfig(**_CLEAN_KW))
    (att,), _ = _run_rounds(cfg, AdversaryConfig(**attack))
    (defended,), _ = _run_rounds(
        cfg, AdversaryConfig(vote_budget=budget, **attack))
    c0 = np.asarray(clean[2]["counts"], np.int64)
    dist_att = np.abs(np.asarray(att[2]["counts"], np.int64) - c0).sum()
    dist_def = np.abs(
        np.asarray(defended[2]["counts"], np.int64) - c0).sum()
    assert int(defended[2]["budget_rejected"]) > 0
    assert dist_def < dist_att


def test_trim_close_defends_sign_flip_poisoning():
    """Coordinate-wise trimming answers the sign-flip/scaled-update
    attack: the defended delta lands closer to the clean aggregate than
    the undefended register sum under the same poisoned cohort."""
    cfg_sum = FediACConfig(a=2, bits=12)
    cfg_trim = FediACConfig(a=2, bits=12, robust_agg="trim", trim_frac=0.3)
    attack = dict(byzantine_frac=0.3, poison_scale=-8.0, seed=1,
                  loss=0.0, participation=1.0)
    (clean,), _ = _run_rounds(cfg_sum, AdversaryConfig(
        seed=1, loss=0.0, participation=1.0))
    (att,), _ = _run_rounds(cfg_sum, AdversaryConfig(**attack))
    (defended,), _ = _run_rounds(cfg_trim, AdversaryConfig(**attack))
    d0 = np.asarray(clean[0], np.float64)
    err_att = np.linalg.norm(np.asarray(att[0], np.float64) - d0)
    err_def = np.linalg.norm(np.asarray(defended[0], np.float64) - d0)
    assert int(defended[2]["trimmed_values"]) > 0
    assert err_def < err_att


def test_clip_ticks_clamps_scaled_updates():
    """Int-domain magnitude clipping engages on the scaled-update attack
    (clipped deposits are counted) and changes the aggregate; at 0 it is
    the identity."""
    cfg = FediACConfig(a=2, bits=12)
    attack = dict(byzantine_frac=0.3, poison_scale=40.0, seed=1,
                  loss=0.0, participation=1.0)
    (att,), _ = _run_rounds(cfg, AdversaryConfig(**attack))
    (clipped,), _ = _run_rounds(cfg, AdversaryConfig(
        clip_ticks=64, **attack))
    assert int(att[2]["clipped_values"]) == 0
    assert int(clipped[2]["clipped_values"]) > 0
    assert not bool(jnp.all(att[0] == clipped[0]))


# ---------------------------------------------------------------------------
# reputation and quarantine: the state machine and its checkpoint path
# ---------------------------------------------------------------------------


def test_reputation_update_state_machine():
    """One update step, pinned: decay + masked signal accumulation, the
    threshold trigger arming the quarantine counter and resetting the
    score to probation (half threshold), then the counter draining."""
    state = {"rep": jnp.asarray([0.0, 2.0], jnp.float32),
             "quarantine": jnp.asarray([0, 0], jnp.int32)}
    dyn = {"rep_decay": 0.5, "rep_threshold": 1.0, "quarantine_rounds": 3}
    part = jnp.asarray([True, True])
    sig = jnp.asarray([0.2, 0.5], jnp.float32)
    st1, stats = reputation_update(state, part=part, signal=sig, dyn=dyn)
    np.testing.assert_allclose(np.asarray(st1["rep"]), [0.2, 0.5])
    np.testing.assert_array_equal(np.asarray(st1["quarantine"]), [0, 3])
    assert int(stats["rep_flagged"]) == 1
    assert int(stats["quarantined"]) == 1
    # quarantined client sits out: no new signal, counter drains, score
    # decays from probation — no re-trigger while suspended
    st2, stats2 = reputation_update(
        st1, part=jnp.asarray([True, False]),
        signal=jnp.zeros(2, jnp.float32), dyn=dyn)
    np.testing.assert_array_equal(np.asarray(st2["quarantine"]), [0, 2])
    assert int(stats2["rep_flagged"]) == 0
    np.testing.assert_allclose(np.asarray(st2["rep"]), [0.1, 0.25])


def test_quarantine_excludes_and_readmits():
    """Core-level engagement: a persistent attack drives flagged clients
    into quarantine, quarantined clients never appear among that round's
    participants, and the counter drains back to re-admission."""
    cfg = FediACConfig(a=2, bits=12)
    net = AdversaryConfig(byzantine_frac=0.4, vote_stuff_frac=0.8,
                          poison_scale=-8.0, rep_decay=0.9,
                          rep_threshold=1.0, rep_z_thresh=1.0,
                          quarantine_rounds=2, loss=0.0,
                          participation=1.0, seed=0)
    rounds, _ = _run_rounds(cfg, net, rounds=8)
    seen_quar = 0
    readmitted = False
    prev_q = None
    for d, r, aux, state_in in rounds:
        q = np.asarray(state_in["quarantine"])
        part = np.asarray(aux["participants"])
        assert not np.any(part & (q > 0))        # exclusion is absolute
        seen_quar = max(seen_quar, int(np.sum(q > 0)))
        if prev_q is not None and np.any((prev_q > 0) & (q == 0)):
            readmitted = True
        prev_q = q
    assert seen_quar > 0                          # the defense engaged
    assert readmitted                             # probation, not a ban


_ADV_NET = AdversaryConfig(byzantine_frac=0.4, vote_stuff_frac=0.8,
                           poison_scale=-8.0, rep_decay=0.9,
                           rep_threshold=1.0, rep_z_thresh=1.0,
                           quarantine_rounds=2, vote_budget=8, seed=4)


def _adv_run(clients, test, rounds, ckpt=None, resume=False):
    return run_federated(clients, test, FLConfig(
        n_clients=6, rounds=rounds, local_steps=2, aggregator="fediac",
        agg_kwargs={"cfg": FediACConfig(a=2, bits=12, robust_agg="trim",
                                        trim_frac=0.25)},
        seed=0, transport="packet", net=_ADV_NET,
        ckpt_path=ckpt, resume=resume))


def test_kill_and_resume_with_quarantine_state(small_fl):
    """Crash-safe recovery composes with the reputation layer: kill a
    defended run mid-quarantine, resume from the checkpoint — the
    FLHistory equals the uninterrupted run's bit-exactly, and the
    checkpointed agg_state carries a *non-empty* quarantine (the property
    is not vacuously passing on cold state)."""
    clients, test = small_fl
    full = _adv_run(clients, test, 4)
    with tempfile.TemporaryDirectory() as td:
        ck = os.path.join(td, "byz.npz")
        _adv_run(clients, test, 2, ckpt=ck)        # the "killed" run
        st_ = load_run_state(ck)
        resumed = _adv_run(clients, test, 4, ckpt=ck, resume=True)
    assert st_["agg_state"] is not None
    assert int(np.sum(np.asarray(st_["agg_state"]["quarantine"]) > 0)) > 0
    assert np.any(np.asarray(st_["agg_state"]["rep"]) > 0)
    assert resumed.acc == full.acc
    assert resumed.loss == full.loss
    assert resumed.wall_clock == full.wall_clock
    assert resumed.traffic_mb == full.traffic_mb
