"""Parity sweeps for the fused round-plan kernels (interpret mode vs the
jnp oracles in kernels/ref.py) — bit-identical by contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gather_quant, ops, ref, vote_pack, vote_popcount

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("tau", [-1.0, 0.0, 0.9])
@pytest.mark.parametrize("blocks", [1, 3])
def test_vote_pack_matches_ref(tau, blocks):
    rows = ref.GROUP * vote_pack.ROWS_PER_BLOCK * blocks
    scores = jax.random.normal(KEY, (rows, ref.LANES))
    got = vote_pack.vote_pack(scores, tau)
    want = ref.vote_pack_ref(scores, jnp.float32(tau))
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vote_pack_flat_padding_never_votes():
    d = 70_001  # ragged: padding lanes must not contribute votes
    scores = jnp.abs(jax.random.normal(KEY, (d,)))
    packed = ops.pack_votes_threshold(scores, 0.0)  # tau 0: every real lane votes
    back = ops.unpack_votes(packed, d)
    np.testing.assert_array_equal(np.asarray(back), np.ones(d, np.uint8))
    total_bits = sum(bin(int(w)).count("1") for w in np.asarray(packed))
    assert total_bits == d  # nothing beyond d voted


@pytest.mark.parametrize("f", [1.0, 117.5, 4000.0])
@pytest.mark.parametrize("density", [0.0, 0.1, 1.0])
def test_gather_quant_matches_ref(f, density):
    rows = gather_quant.BLOCK_ROWS * 2
    u = jax.random.normal(KEY, (rows, ref.LANES)) * 3
    uni = jax.random.uniform(jax.random.PRNGKey(1), (rows, ref.LANES))
    sel = (jax.random.uniform(jax.random.PRNGKey(2), (rows, ref.LANES))
           < density).astype(jnp.int32)
    qg, rg = gather_quant.gather_quant(u, uni, sel, jnp.float32(f))
    qw, rw = ref.gather_quant_ref(u, uni, sel, jnp.float32(f))
    np.testing.assert_array_equal(np.asarray(qg), np.asarray(qw))
    np.testing.assert_array_equal(np.asarray(rg), np.asarray(rw))
    # unselected coordinates upload nothing and keep their full residual
    off = np.asarray(sel) == 0
    assert np.all(np.asarray(qg)[off] == 0)
    np.testing.assert_array_equal(np.asarray(rg)[off], np.asarray(u)[off])


@pytest.mark.parametrize("n", [1, 8, 64])
def test_popcount_bitplane_matches_ref(n):
    w3 = jax.random.bits(KEY, (n, vote_popcount.ROWS_PER_BLOCK * 2, ref.LANES),
                         jnp.uint32)
    got = vote_popcount.popcount_accum(w3)
    want = ref.popcount_accum_ref(w3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_quant_flat_ragged_roundtrip():
    d = 123_457
    u = jax.random.normal(KEY, (d,))
    uni = jax.random.uniform(jax.random.PRNGKey(3), (d,))
    sel = (jax.random.uniform(jax.random.PRNGKey(4), (d,)) < 0.05).astype(jnp.uint8)
    q, res = ops.gather_quant_flat(u, uni, sel, 55.0)
    qw, rw = ref.gather_quant_ref(u, uni, sel, jnp.float32(55.0))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qw))
    np.testing.assert_array_equal(np.asarray(res), np.asarray(rw))
