"""Shared config validation (``repro.validate``): every field bound raises.

One parametrized sweep per config class.  The helpers guarantee a
uniform failure shape — ``ValueError: <field> must be <requirement>,
got <value>`` — so each case also checks the field name appears in the
message.
"""

import math

import pytest

from repro.core.fediac import FediACConfig
from repro.netsim import FaultConfig, NetConfig
from repro.robust import AdversaryConfig
from repro.sweep import ScenarioSpec
from repro.training import FLConfig
from repro.validate import (check_at_least, check_choice,
                            check_finite_at_least, check_interval,
                            check_positive_finite, require)

NAN = float("nan")


def _rejects(cls, kw):
    field = next(iter(kw))
    with pytest.raises(ValueError, match=field):
        cls(**kw)


@pytest.mark.parametrize("kw", [
    {"k_frac": 0.0}, {"k_frac": 1.5}, {"k_frac": -0.1}, {"k_frac": NAN},
    {"capacity_frac": 0.0}, {"capacity_frac": 1.01},
    {"a_frac": 0.0}, {"a_frac": 2.0},
    {"a": 0}, {"a": -3},
    {"bits": 0}, {"vote_chunk": 0}, {"block_size": 0},
    {"stream_chunk": -1}, {"consensus_floor": -1},
    {"alpha": float("inf")}, {"alpha": NAN},
    {"vote_mode": "best"}, {"compact_mode": "dense"},
    {"vote_wire": "tcp"}, {"granularity": "layer"},
    {"robust_agg": "avg"}, {"trim_frac": 0.5}, {"trim_frac": -0.1},
    {"trim_frac": NAN},
])
def test_fediac_config_rejects(kw):
    _rejects(FediACConfig, kw)


@pytest.mark.parametrize("kw", [
    {"n_clients": 0}, {"rounds": -1}, {"local_steps": 0}, {"batch": 0},
    {"lr0": 0.0}, {"lr0": -1.0}, {"lr0": NAN},
    {"lr_tau": 0.0}, {"local_train_s": -0.1}, {"local_train_s": NAN},
    {"transport": "carrier-pigeon"}, {"ckpt_every": 0},
])
def test_fl_config_rejects(kw):
    _rejects(FLConfig, kw)


@pytest.mark.parametrize("kw", [
    {"k_frac": 0.0}, {"capacity_frac": 1.5}, {"a_frac": -0.2}, {"a": 0},
    {"bits": 0}, {"vote_mode": "x"}, {"compact_mode": "x"},
    {"n_clients": 0}, {"rounds": 0}, {"local_steps": 0}, {"batch": 0},
    {"data_n": 0}, {"data_dim": 0}, {"data_classes": 0}, {"n_leaves": 0},
    {"lr0": 0.0}, {"lr_tau": -1.0}, {"beta": 0.0},
    {"test_frac": 0.0}, {"test_frac": 1.0},
    {"dist": "zipf"}, {"switch": "mid"}, {"transport": "x"},
    {"local_train_s": -1.0},
    {"loss": 1.0}, {"loss": -0.1}, {"participation": 0.0},
    {"straggler_frac": 1.1},
    {"ge_p_gb": -0.1}, {"ge_p_bg": 2.0}, {"ge_loss_bad": 1.5},
    {"crash_rate": -1.0}, {"crash_p2_frac": 2.0}, {"dup_rate": 1.2},
    {"reg_reset_rate": -0.5},
    {"reorder_jitter_s": -1.0}, {"backoff_s": NAN},
    {"quorum_floor": -1}, {"round_retries": -1}, {"consensus_floor": -2},
    {"byzantine_frac": 1.0}, {"byzantine_frac": -0.1},
    {"collusion_frac": 0.3},                 # > byzantine_frac (0 default)
    {"vote_stuff_frac": 1.5}, {"poison_scale": NAN},
    {"vote_budget": -1}, {"clip_ticks": -1},
    {"robust_agg": "huber"}, {"trim_frac": 0.5},
    {"rep_decay": 1.2}, {"rep_threshold": 0.0}, {"rep_z_thresh": -1.0},
    {"quarantine_rounds": -1},
])
def test_scenario_spec_rejects(kw):
    _rejects(ScenarioSpec, kw)


@pytest.mark.parametrize("kw", [
    {"loss": 1.0}, {"loss": -0.01}, {"participation": 0.0},
    {"participation": 1.5}, {"straggler_frac": -0.1},
    {"straggler_slowdown": 0.5}, {"straggler_slowdown": float("inf")},
    {"vote_deadline_s": 0.0}, {"vote_deadline_s": -1.0},
    {"vote_deadline_s": float("inf")},
    {"rto_s": 0.0}, {"rto_s": NAN},
    {"max_retries": 0}, {"n_leaves": 0}, {"memory_slots": 0}, {"mtu": 0},
])
def test_net_config_rejects(kw):
    _rejects(NetConfig, kw)


@pytest.mark.parametrize("kw", [
    {"ge_p_gb": 1.5}, {"ge_p_bg": -0.1}, {"ge_loss_bad": 2.0},
    {"crash_rate": -0.5}, {"crash_p2_frac": 1.1}, {"dup_rate": 2.0},
    {"reg_reset_rate": -1.0},
    {"ge_p_gb": 0.1, "ge_p_bg": 0.0},       # absorbing bad state
    {"reorder_jitter_s": -1.0}, {"register_policy": "clamp"},
    {"quorum_floor": -1}, {"round_retries": -1}, {"backoff_s": NAN},
    {"rto_s": 0.0},                          # inherited NetConfig bound
])
def test_fault_config_rejects(kw):
    with pytest.raises(ValueError):
        FaultConfig(**kw)


@pytest.mark.parametrize("kw", [
    {"byzantine_frac": -0.1}, {"byzantine_frac": 1.0},
    {"collusion_frac": -0.2}, {"collusion_frac": 1.0},
    {"collusion_frac": 0.3},                 # exceeds byzantine_frac=0
    {"byzantine_frac": 0.1, "collusion_frac": 0.2},
    {"vote_stuff_frac": -0.1}, {"vote_stuff_frac": 1.5},
    {"poison_scale": float("inf")}, {"poison_scale": NAN},
    {"vote_budget": -1}, {"clip_ticks": -2},
    {"rep_decay": -0.1}, {"rep_decay": 1.5},
    {"rep_threshold": 0.0}, {"rep_threshold": -2.0},
    {"rep_z_thresh": -1.0}, {"rep_z_thresh": float("inf")},
    {"quarantine_rounds": -1},
    {"crash_rate": 2.0},                     # inherited FaultConfig bound
    {"rto_s": 0.0},                          # inherited NetConfig bound
])
def test_adversary_config_rejects(kw):
    with pytest.raises(ValueError):
        AdversaryConfig(**kw)


def test_adversary_and_async_mutually_exclusive():
    with pytest.raises(ValueError, match="mutually exclusive"):
        ScenarioSpec(adversary=True, async_agg=True)


def test_boundary_values_accepted():
    FediACConfig(k_frac=1.0, capacity_frac=1.0, a_frac=1.0, a=1, bits=1,
                 consensus_floor=0)
    FLConfig(rounds=0, ckpt_every=1, local_train_s=0.0)
    ScenarioSpec(loss=0.0, participation=1.0, straggler_frac=1.0,
                 test_frac=0.5)
    NetConfig(straggler_slowdown=1.0, vote_deadline_s=1e-6, max_retries=1)
    NetConfig(vote_deadline_s=None)
    FaultConfig(ge_p_gb=0.0, ge_p_bg=0.0)    # no bad state entered: legal
    FediACConfig(robust_agg="median", trim_frac=0.49)
    AdversaryConfig()                        # all-zero = plain packet core
    AdversaryConfig(byzantine_frac=0.25, collusion_frac=0.25,
                    vote_stuff_frac=1.0, poison_scale=-8.0,
                    rep_decay=1.0, quarantine_rounds=0)
    ScenarioSpec(adversary=True, chaos=True, byzantine_frac=0.25,
                 robust_agg="trim", trim_frac=0.3)   # faults compose


def test_helpers_message_shape():
    with pytest.raises(ValueError, match=r"x must be in \(0, 1\], got 0"):
        check_interval("x", 0, 0, 1, lo_open=True)
    with pytest.raises(ValueError, match="y must be >= 3"):
        check_at_least("y", 2, 3)
    with pytest.raises(ValueError, match="z must be finite and >= 0"):
        check_finite_at_least("z", math.inf, 0)
    with pytest.raises(ValueError, match="w must be positive and finite"):
        check_positive_finite("w", 0)
    with pytest.raises(ValueError, match="m must be one of 'a', 'b'"):
        check_choice("m", "c", ("a", "b"))
    with pytest.raises(ValueError, match="q must be prime, got 4"):
        require(False, "q", "prime", 4)
    check_interval("ok", 0.5, 0, 1)
    require(True, "ok", "anything", None)
