"""Sweep engine (DESIGN.md §10): fleet/sequential bit-identity, dynamic
vote-threshold batching, chunking, resume, and the grid registry."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fediac import FediACConfig
from repro.core.round_plan import build_round_plan
from repro.sweep import (GRIDS, ScenarioSpec, cell_key, get_grid,
                         run_cell_sequential, run_sweep, smoke_grid)

TINY = dict(n_clients=4, rounds=3, local_steps=2, batch=8, hidden=(16,),
            data_n=500, data_dim=12, data_classes=5)


def _assert_same(h_seq, h_fleet, ctx=""):
    assert h_seq.acc == h_fleet.acc, f"{ctx}: acc"
    assert h_seq.loss == h_fleet.loss, f"{ctx}: loss"
    assert h_seq.wall_clock == h_fleet.wall_clock, f"{ctx}: wall_clock"
    assert h_seq.traffic_mb == h_fleet.traffic_mb, f"{ctx}: traffic_mb"


# ---------------------------------------------------------------------------
# bit-identity: the fleet program == the sequential loop, per cell
# ---------------------------------------------------------------------------

def test_fleet_bit_identical_fediac_dynamic_threshold():
    """Cells differing in vote threshold a, data skew AND seed share one
    vmapped program; every cell equals its sequential run exactly."""
    specs = [ScenarioSpec(name="a2", algorithm="fediac", a=2, **TINY),
             ScenarioSpec(name="a3b5", algorithm="fediac", a=3, beta=5.0,
                          **TINY)]
    assert specs[0].batch_signature() == specs[1].batch_signature()
    result = run_sweep(specs, (0, 1))
    assert len(result) == 4
    for cr in result:
        _assert_same(run_cell_sequential(cr.spec, cr.seed), cr.history,
                     cr.key)


@pytest.mark.parametrize("algo,overrides", [
    ("libra", (("k_frac", 0.02), ("hot_frac", 0.02))),
    ("omnireduce", (("k_frac", 0.05),)),
    ("topk", (("k_frac", 0.02),)),
])
def test_fleet_bit_identical_baselines(algo, overrides):
    """Stateful (libra EMA) and dynamic-wire (omnireduce block counts)
    baselines survive the fleet axis bit-identically."""
    spec = ScenarioSpec(name=algo, algorithm=algo, agg_overrides=overrides,
                        **TINY)
    cr = run_sweep([spec], (0,)).cells[0]
    _assert_same(run_cell_sequential(spec, 0), cr.history, algo)


def test_fleet_chunking_invariant():
    """max_fleet=1 (degenerate chunks) and one big batch agree exactly."""
    specs = [ScenarioSpec(name="a2", algorithm="fediac", a=2, **TINY)]
    big = run_sweep(specs, (0, 1), max_fleet=8)
    small = run_sweep(specs, (0, 1), max_fleet=1)
    for b, s in zip(big, small):
        assert b.key == s.key
        _assert_same(b.history, s.history, b.key)


# ---------------------------------------------------------------------------
# dynamic vote threshold
# ---------------------------------------------------------------------------

def test_round_plan_traced_threshold_matches_static():
    cfg = FediACConfig(capacity_frac=0.2)
    counts = jnp.asarray(np.random.default_rng(0).integers(0, 9, 4096),
                         jnp.int32)
    static = build_round_plan(counts, cfg, 8)

    traced = jax.jit(lambda a: build_round_plan(counts, cfg, 8, a=a))(
        jnp.int32(cfg.threshold(8)))
    assert jnp.array_equal(static.idx, traced.idx)
    assert jnp.array_equal(static.keep, traced.keep)


# ---------------------------------------------------------------------------
# grouping / batchability
# ---------------------------------------------------------------------------

def test_batch_signature_partitions():
    a2 = ScenarioSpec(algorithm="fediac", a=2, **TINY)
    a4 = ScenarioSpec(algorithm="fediac", a=4, lr0=0.05, beta=1.0, **TINY)
    sw = ScenarioSpec(algorithm="switchml", agg_overrides=(("bits", 12),),
                      **TINY)
    pkt = ScenarioSpec(algorithm="fediac", a=2, transport="packet", **TINY)
    assert a2.batch_signature() == a4.batch_signature()
    assert a2.batch_signature() != sw.batch_signature()
    assert a2.batchable() and sw.batchable()
    # packet FediAC batches through the netsim round core (DESIGN.md §13);
    # loss/participation/straggler rates and the net seed ride as traced
    # per-cell scalars, so a whole grid shares one compiled program —
    # while the transport itself still splits the group from memory cells
    pkt_lossy = ScenarioSpec(algorithm="fediac", a=2, transport="packet",
                             loss=0.05, participation=0.5,
                             straggler_frac=0.25, net_seed=3, **TINY)
    assert pkt.batchable() and pkt_lossy.batchable()
    assert pkt.batch_signature() == pkt_lossy.batch_signature()
    assert pkt.batch_signature() != a2.batch_signature()
    # packet baselines and the streaming engine keep the sequential path
    pkt_sw = ScenarioSpec(algorithm="switchml", transport="packet", **TINY)
    pkt_stream = ScenarioSpec(algorithm="fediac", a=2, transport="packet",
                              engine="stream", **TINY)
    assert not pkt_sw.batchable() and not pkt_stream.batchable()
    # pricing-only fields never split a group
    hi = ScenarioSpec(algorithm="fediac", a=2, switch="high", **TINY)
    lo = ScenarioSpec(algorithm="fediac", a=2, switch="low", **TINY)
    assert hi.batch_signature() == lo.batch_signature()


# ---------------------------------------------------------------------------
# packet-transport cells on the fleet axis (DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_packet_fleet_bit_identical_mixed_network_conditions():
    """Lossless, lossy+partial and straggler packet cells — different vote
    thresholds and net seeds — ride ONE vmapped program and each equals
    its sequential PacketTransport run exactly (history bit-identity)."""
    specs = [ScenarioSpec(name="pk-clean", algorithm="fediac", a=2,
                          transport="packet", **TINY),
             ScenarioSpec(name="pk-lossy", algorithm="fediac", a=2,
                          transport="packet", loss=0.05, participation=0.5,
                          net_seed=3, **TINY),
             ScenarioSpec(name="pk-strag", algorithm="fediac", a=3,
                          transport="packet", straggler_frac=0.5,
                          net_seed=1, **TINY)]
    assert len({s.batch_signature() for s in specs}) == 1
    result = run_sweep(specs, (0,))
    for cr in result:
        _assert_same(run_cell_sequential(cr.spec, cr.seed), cr.history,
                     cr.key)


def test_packet_fleet_matches_memory_when_lossless():
    """The fleet-batched lossless packet cell learns the identical
    trajectory as the in-memory transport (same accuracy per round)."""
    pkt = ScenarioSpec(name="pk", algorithm="fediac", a=2,
                       transport="packet", **TINY)
    mem = ScenarioSpec(name="mem", algorithm="fediac", a=2, **TINY)
    res = run_sweep([pkt, mem], (0,))
    h = {c.spec.name: c.history for c in res}
    assert h["pk"].acc == h["mem"].acc
    assert h["pk"].traffic_mb == h["mem"].traffic_mb


def test_async_fleet_bit_identical_quorum_grid():
    """Async quorum-or-deadline cells (DESIGN.md §17) — different vote
    thresholds, quorum fractions, staleness knobs and net conditions —
    ride ONE vmapped program with the late-update carry threaded as a
    batched state lane; each cell equals its sequential PacketTransport
    run exactly (history bit-identity, n_up_wire byte pricing included)."""
    base = dict(algorithm="fediac", transport="packet", async_agg=True,
                staleness_mode="poly", **TINY)
    specs = [ScenarioSpec(name="aq-half", a=2, quorum_frac=0.5,
                          straggler_frac=0.5, net_seed=3, **base),
             ScenarioSpec(name="aq-most", a=3, quorum_frac=0.75,
                          staleness_gamma=2.0, loss=0.05,
                          participation=0.75, net_seed=1, **base)]
    assert len({s.batch_signature() for s in specs}) == 1
    assert all(s.batchable() for s in specs)
    # async_agg is structural: the group never mixes with sync packet cells
    sync = ScenarioSpec(algorithm="fediac", a=2, transport="packet", **TINY)
    assert specs[0].batch_signature() != sync.batch_signature()
    result = run_sweep(specs, (0,))
    for cr in result:
        _assert_same(run_cell_sequential(cr.spec, cr.seed), cr.history,
                     cr.key)


def test_cell_key_stable_and_flat():
    spec = ScenarioSpec(name="x/y", algorithm="fediac", a=2, **TINY)
    k = cell_key(spec, 7)
    assert k == cell_key(spec, 7) and "/" not in k
    assert k != cell_key(spec, 8)


# ---------------------------------------------------------------------------
# resume
# ---------------------------------------------------------------------------

def test_resume_skips_finished_cells(tmp_path):
    progress = str(tmp_path / "sweep_progress.npz")
    a2 = ScenarioSpec(name="a2", algorithm="fediac", a=2, **TINY)
    a3 = ScenarioSpec(name="a3", algorithm="fediac", a=3, **TINY)

    first = run_sweep([a2], (0,), progress_path=progress)
    assert not first.cells[0].resumed

    # same sweep again: everything loads from disk, nothing recomputes
    again = run_sweep([a2], (0,), progress_path=progress)
    assert again.cells[0].resumed
    _assert_same(first.cells[0].history, again.cells[0].history, "resume")

    # a grown grid resumes the finished cell and computes only the new one
    grown = run_sweep([a2, a3], (0,), progress_path=progress)
    by_key = grown.by_key()
    assert by_key[cell_key(a2, 0)].resumed
    assert not by_key[cell_key(a3, 0)].resumed
    _assert_same(run_cell_sequential(a3, 0), by_key[cell_key(a3, 0)].history,
                 "grown")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_grid_registry():
    for name in GRIDS:
        grid = get_grid(name)
        assert grid and all(isinstance(s, ScenarioSpec) for s in grid), name
    with pytest.raises(KeyError):
        get_grid("nope")
    assert all(s.batchable() for s in smoke_grid())
    # the dataplane grid rides the fleet axis too (DESIGN.md §13), and its
    # cells all share one compiled round program
    dp = get_grid("dataplane")
    assert all(s.batchable() for s in dp)
    assert len({s.batch_signature() for s in dp}) == 1


def test_packet_cells_forced_sequential_agree_with_fleet():
    """``sequential=True`` (the bit-identity oracle path) routes packet
    cells through run_federated + PacketTransport; the default fleet path
    must reproduce it exactly."""
    spec = ScenarioSpec(name="pkt", algorithm="fediac", a=2,
                        transport="packet", loss=0.02, **TINY)
    res = run_sweep([spec], (0,))
    seq = run_sweep([spec], (0,), sequential=True)
    _assert_same(seq.cells[0].history, res.cells[0].history, "packet")
