"""End-to-end behaviour of the FL system + switch simulator (paper Sec. V)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fediac import FediACConfig
from repro.data import classification, partition_dirichlet
from repro.switch import ProgrammableSwitch, SwitchProfile, client_rates, round_wall_clock
from repro.training import FLConfig, run_federated


@pytest.fixture(scope="module")
def fl_setup():
    data = classification(n=3000, dim=32, n_classes=10, seed=0)
    train, test = data.test_split(0.25)
    clients = partition_dirichlet(train, 10, beta=0.5, seed=0)
    return clients, test


def _run(fl_setup, name, rounds=15, **kw):
    clients, test = fl_setup
    cfg = FLConfig(n_clients=10, rounds=rounds, local_steps=3, aggregator=name,
                   agg_kwargs=kw, seed=0)
    return run_federated(clients, test, cfg)


def test_fediac_learns(fl_setup):
    h = _run(fl_setup, "fediac", cfg=FediACConfig(a=2, bits=12))
    assert h.acc[-1] > 0.55                     # learns
    assert h.loss[-1] < h.loss[0]               # loss decreases
    assert all(np.diff(h.wall_clock) > 0)       # clock advances


def test_fediac_approaches_fedavg(fl_setup):
    h_avg = _run(fl_setup, "fedavg")
    h_fed = _run(fl_setup, "fediac", cfg=FediACConfig(a=2, bits=12, k_frac=0.1,
                                                      capacity_frac=0.1))
    assert h_fed.acc[-1] > h_avg.acc[-1] - 0.12  # compressed stays close


def test_fediac_traffic_beats_baselines(fl_setup):
    """The paper's headline: FediAC shrinks traffic vs SwitchML/Top-k."""
    h_fed = _run(fl_setup, "fediac", cfg=FediACConfig(a=2, bits=12))
    h_sml = _run(fl_setup, "switchml", bits=12)
    h_avg = _run(fl_setup, "fedavg")
    assert h_fed.traffic_mb[-1] < h_sml.traffic_mb[-1] < h_avg.traffic_mb[-1]


def test_noniid_degree_ordering(fl_setup):
    """Milder non-IID (larger beta) should not hurt accuracy (Fig. 3 trend)."""
    data = classification(n=3000, dim=32, n_classes=10, seed=1)
    train, test = data.test_split(0.25)
    accs = {}
    for beta in (0.3, 5.0):
        clients = partition_dirichlet(train, 10, beta=beta, seed=0)
        cfg = FLConfig(n_clients=10, rounds=15, local_steps=3, aggregator="fediac",
                       agg_kwargs={"cfg": FediACConfig(a=2, bits=12)}, seed=0)
        accs[beta] = run_federated(clients, test, cfg).acc[-1]
    assert accs[5.0] >= accs[0.3] - 0.05


# ---------------------------------------------------------------------------
# switch simulator
# ---------------------------------------------------------------------------

def test_ps_integer_only():
    ps = ProgrammableSwitch()
    with pytest.raises(TypeError):
        ps.aggregate_aligned(np.ones((2, 8), np.float32))


def test_ps_motivation_example():
    """Sec. III-B worked example: Top-2 costs 4 PS aggregations; FediAC costs
    3 (1 vote-array aggregation + 2 aligned value additions)."""
    ps = ProgrammableSwitch(memory_slots=2)
    u1 = np.array([5, 4, 3, 2, 1]); u2 = np.array([1, 3, 4, 5, 2])
    # Top-2 without consensus: clients upload disjoint indices
    _, stats_sparse = ps.aggregate_sparse(
        [np.array([0, 1]), np.array([3, 2])],
        [u1[[0, 1]], u2[[3, 2]]], d=5)
    topk_cost = stats_sparse.aggregation_ops + stats_sparse.server_redirects
    assert topk_cost == 4                     # the paper's "4 aggregations"
    assert stats_sparse.server_redirects > 0  # PS could not align all of it
    # FediAC: 1-bit votes (1 aggregation: 5 bits fit one slot) -> GIA {1,2}
    votes = np.stack([np.array([1, 1, 1, 0, 0]), np.array([0, 1, 1, 1, 0])])
    _, stats_votes = ps.aggregate_aligned(votes.astype(np.int64))
    gia = np.flatnonzero(votes.sum(0) >= 2)[:2]
    out2, stats_aligned = ps.aggregate_aligned(np.stack([u1[gia], u2[gia]]))
    fediac_cost = 1 + stats_aligned.aggregation_ops   # 1 vote op + 2 adds
    assert fediac_cost == 3 < topk_cost
    assert stats_aligned.server_redirects == 0
    np.testing.assert_array_equal(out2, u1[gia] + u2[gia])


def test_queuing_low_perf_slower():
    rates = client_rates(20, 0)
    kw = dict(packets_per_client=500, download_packets=500, rates=rates,
              local_train_s=0.1)
    t_hi = round_wall_clock(profile=SwitchProfile.high(), **kw)
    t_lo = round_wall_clock(profile=SwitchProfile.low(), **kw)
    assert t_lo >= t_hi > 0


def test_queuing_unaligned_penalty():
    rates = client_rates(20, 0)
    kw = dict(packets_per_client=2000, download_packets=500, rates=rates,
              local_train_s=0.0, profile=SwitchProfile.low())
    assert round_wall_clock(aligned=False, **kw) > round_wall_clock(aligned=True, **kw)


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4), {"c": jnp.zeros(2)}]}
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree, step=7)
    back, step = load_checkpoint(p, like=tree)
    assert step == 7
    for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
