"""End-to-end behaviour of the paper's system: the full FediAC round trip
through the model substrate on a single device (multi-device paths live in
test_distributed.py)."""

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.fediac import FediACConfig, aggregate_stack
from repro.models.model import init_params, loss_fn


def test_fediac_training_reduces_loss_vs_dense():
    """A tiny LM trained with FediAC-compressed aggregation must track the
    dense-FedAvg trajectory (same clients, same data, same seeds)."""
    cfg = get_smoke("qwen3_0p6b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    flat0, unravel = jax.flatten_util.ravel_pytree(params)

    n_clients, rounds, lr = 4, 6, 0.5
    toks = jax.random.randint(jax.random.PRNGKey(1), (n_clients, 2, 32), 0, cfg.vocab)

    @jax.jit
    def client_grad(flat, c):
        p = unravel(flat)
        batch = {"tokens": toks[c], "targets": jnp.roll(toks[c], -1, axis=1)}
        g = jax.grad(lambda pp: loss_fn(pp, cfg, batch))(p)
        return jax.flatten_util.ravel_pytree(g)[0]

    @jax.jit
    def mean_loss(flat):
        p = unravel(flat)
        return jnp.mean(jnp.stack([
            loss_fn(p, cfg, {"tokens": toks[c], "targets": jnp.roll(toks[c], -1, 1)})
            for c in range(n_clients)]))

    agg_cfg = FediACConfig(k_frac=0.2, a=1, bits=14, capacity_frac=0.2)
    traj = {}
    for mode in ("dense", "fediac"):
        flat = flat0
        res = jnp.zeros((n_clients, flat.size))
        losses = [float(mean_loss(flat))]
        for r in range(rounds):
            u = jnp.stack([lr * client_grad(flat, c) for c in range(n_clients)])
            if mode == "dense":
                delta = u.mean(axis=0)
            else:
                delta, res, _, _ = aggregate_stack(u + res, agg_cfg,
                                                   jax.random.PRNGKey(10 + r))
            flat = flat - delta
            losses.append(float(mean_loss(flat)))
        traj[mode] = losses

    assert traj["dense"][-1] < traj["dense"][0] - 0.3
    assert traj["fediac"][-1] < traj["fediac"][0] - 0.2
    # compressed trajectory stays within a band of the dense one
    assert traj["fediac"][-1] < traj["dense"][-1] + 0.8, traj
