"""Pallas flash-attention kernel vs the blockwise-JAX oracle (which is
itself oracle-checked against dense attention in test_models.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import attention as A


@pytest.mark.parametrize("b,s,h,hk,dh", [
    (1, 1024, 4, 2, 64),     # GQA
    (2, 512, 8, 8, 32),      # MHA
    (1, 512, 4, 1, 128),     # MQA
])
@pytest.mark.parametrize("window", [0, 256])
def test_flash_matches_blockwise(b, s, h, hk, dh, window):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hk, dh))
    pos = jnp.arange(s)
    got = flash_attention(q, k, v, causal=True, window=window)
    want = A._blockwise_attention(q, k, v, pos, pos, True, window,
                                  1.0 / dh ** 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_bf16():
    b, s, h, hk, dh = 1, 1024, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hk, dh), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hk, dh), jnp.bfloat16)
    pos = jnp.arange(s)
    got = flash_attention(q, k, v)
    want = A._blockwise_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                                  v.astype(jnp.float32), pos, pos, True, 0,
                                  1.0 / dh ** 0.5)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=2e-2, rtol=2e-2)
