"""Quickstart: FediAC in 40 lines.

Twenty clients jointly average their model updates through the two-phase
consensus compression of the paper — voting (1 bit/coordinate), GIA
thresholding, unbiased integer quantization, aligned compact aggregation —
and we inspect how much wire traffic that saved.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import FediACConfig, aggregate_stack

N_CLIENTS, DIM = 20, 100_000

key = jax.random.PRNGKey(0)
# synthetic client updates: heavy-tailed (power-law-ish), as Def. 1 assumes
base = jax.random.normal(key, (N_CLIENTS, DIM)) ** 3

cfg = FediACConfig(
    k_frac=0.05,        # each client votes 5% of coordinates (paper Sec. V-A3)
    a=3,                # >= 3 of 20 clients must agree (the GIA threshold)
    bits=12,            # integer quantization width (Cor. 1 lower-bounds it)
    capacity_frac=0.05, # compact aggregation buffer C = 5% of d
)

delta, residuals, counts, traffic = aggregate_stack(base, cfg, jax.random.PRNGKey(1))

dense = base.mean(axis=0)
err = jnp.linalg.norm(delta - dense) / jnp.linalg.norm(dense)

print(f"coordinates selected by consensus : {int((counts >= 3).sum()):,} / {DIM:,}")
print(f"phase-1 bytes/client (votes)      : {traffic.phase1_bytes:,}")
print(f"phase-2 bytes/client (values)     : {traffic.phase2_bytes:,}")
print(f"dense FedAvg bytes/client         : {traffic.dense_bytes:,}")
print(f"traffic reduction                 : {traffic.reduction:.1%}")
print(f"relative error vs dense mean      : {float(err):.3f}")
print("residual (error feedback) keeps the rest for the next round:",
      f"|e| = {float(jnp.abs(residuals).mean()):.4f}")
