"""Distributed LM training with FediAC as the gradient collective.

Runs a reduced assigned-architecture config on an emulated multi-device
mesh: clients = data-axis shards, E local SGD steps each, FediAC compressed
aggregation inside shard_map (each model shard acts as one programmable
switch for its slice of the coordinates).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/distributed_train.py --arch qwen3-0.6b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_smoke
from repro.data.synthetic import lm_batches
from repro.launch.mesh import make_test_mesh
from repro.models.model import init_params
from repro.training.dist_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--aggregator", default="fediac", choices=["fediac", "dense"])
    args = ap.parse_args()

    if len(jax.devices()) < 2:
        raise SystemExit("run with XLA_FLAGS=--xla_force_host_platform_device_count=8")

    cfg = get_smoke(args.arch).with_(aggregator=args.aggregator)
    mesh = make_test_mesh()
    print(f"mesh {dict(mesh.shape)}  arch {cfg.name}  clients/data-shards: "
          f"{mesh.shape['data']}  E={cfg.fl_local_steps} local steps")

    bundle = make_train_step(cfg, mesh, lr=0.2)
    with mesh:
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=jax.tree_util.tree_map(
                             lambda s: NamedSharding(mesh, s),
                             bundle.params_spec))(jax.random.PRNGKey(0))
        residual = jax.tree_util.tree_map(
            lambda p: jnp.zeros((bundle.n_clients, *p.shape), jnp.float32), params)
        step = jax.jit(bundle.step)
        rng = np.random.default_rng(0)
        key = jax.random.PRNGKey(1)
        t0 = time.time()
        for i, b in enumerate(lm_batches(rng, cfg.vocab, 8, 64, args.steps)):
            key, sk = jax.random.split(key)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, residual, m = step(params, residual, batch, sk)
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"|mean update| {float(m['update_norm']):.4f}  "
                  f"[{time.time() - t0:5.1f}s]")


if __name__ == "__main__":
    main()
