"""FediAC through the executable packet dataplane (DESIGN.md §9, §13).

Degrades the network around the same federated task — packet loss with
retransmission, partial client participation, stragglers (at NetConfig's
default 4x slowdown; the vote-quorum *deadline* policy is exercised by
``tests/test_netsim.py`` and the ``PacketTransport`` API directly, since
``ScenarioSpec`` does not sweep it), and a two-level leaf -> root switch
hierarchy — and runs every scenario through the *batched packet fleet*:
since the
jittable fixed-shape round core (DESIGN.md §13), a whole grid of network
conditions shares ONE ``jit(vmap)`` round program inside ``run_sweep``
instead of paying a fresh XLA compile per scenario.  Lossless full
participation is bit-exact with the in-memory engine, so every accuracy
difference you see below is *caused by the network*, not by simulator
drift.

The hierarchy cell (a different switch count changes the compiled
program's structure) compiles its own one-cell fleet group in the same
sweep call; ``--sequential`` forces every cell through the per-cell
``run_federated`` path — the fleet's bit-identity oracle — for a
side-by-side wall-clock comparison.

  PYTHONPATH=src python examples/fl_lossy_network.py [--rounds 30]
      [--clients 10] [--loss 0.05] [--participation 0.5] [--leaves 2]
      [--trace run.jsonl]

``--trace`` records the whole sweep through a ``repro.obs``
RecordingProbe (DESIGN.md §15) — per-round spans, metrics and the jit
compile/execute split land in the JSONL file, and

  PYTHONPATH=src python -m benchmarks.obs_report run.jsonl

renders the round report.  Tracing never perturbs results: the probe
only observes stats the engines already return, so the table below is
bit-identical with and without it.
"""

import argparse
import time

from repro.sweep import run_sweep
from repro.sweep.spec import ScenarioSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--loss", type=float, default=0.05)
    ap.add_argument("--participation", type=float, default=0.5)
    ap.add_argument("--leaves", type=int, default=2)
    ap.add_argument("--sequential", action="store_true",
                    help="force the per-cell run_federated path (the "
                         "fleet's bit-identity oracle) for comparison")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record a repro.obs JSONL trace of the sweep; "
                         "render it with python -m benchmarks.obs_report")
    args = ap.parse_args()

    task = dict(algorithm="fediac", a=2, bits=12, n_clients=args.clients,
                rounds=args.rounds, local_steps=3, dist="noniid", beta=0.5,
                data_n=6000, data_dim=32, data_classes=10, test_frac=0.2)

    # The flat packet scenarios share one batch signature: loss,
    # participation, straggler fraction and the net seed ride as traced
    # per-cell inputs of a single compiled round program (the memory cell
    # and the hierarchy cell compile separately).
    specs = [
        ScenarioSpec(name="memory (analytic)", **task),
        ScenarioSpec(name="packet lossless", transport="packet", **task),
        ScenarioSpec(name=f"packet loss={args.loss:g}", transport="packet",
                     loss=args.loss, net_seed=1, **task),
        ScenarioSpec(name=f"packet part={args.participation:g}",
                     transport="packet", participation=args.participation,
                     net_seed=1, **task),
        ScenarioSpec(name="packet stragglers=0.3", transport="packet",
                     straggler_frac=0.3, net_seed=1, **task),
        ScenarioSpec(name=f"packet {args.leaves}-leaf tree",
                     transport="packet", n_leaves=args.leaves, **task),
    ]
    packet = [s for s in specs if s.transport == "packet"
              and s.n_leaves == 1]
    assert len({s.batch_signature() for s in packet}) == 1, \
        "the flat packet scenarios must share one fleet program"

    probe = None
    if args.trace:
        from repro.obs import RecordingProbe
        probe = RecordingProbe(args.trace, profiler=True)
        probe.run_start(kind="fl_lossy_network", scenarios=len(specs),
                        rounds=args.rounds, n_clients=args.clients)

    t0 = time.perf_counter()
    result = run_sweep(specs, (0,), sequential=args.sequential, probe=probe)
    dt = time.perf_counter() - t0
    if probe is not None:
        probe.close()

    mode = "sequential" if args.sequential else "fleet"
    print(f"{len(specs)} scenarios in {dt:.1f}s ({mode})")
    print(f"{'scenario':26s} {'final acc':>9s} {'wall clock':>11s} {'traffic':>10s}")
    for cr in result:
        h = cr.history
        print(f"{cr.spec.name:26s} {h.acc[-1]:9.4f} {h.wall_clock[-1]:10.2f}s "
              f"{h.traffic_mb[-1]:9.2f}MB")
    if args.trace:
        print(f"\ntrace: {args.trace} — render with\n"
              f"  PYTHONPATH=src python -m benchmarks.obs_report {args.trace}")


if __name__ == "__main__":
    main()
