"""FediAC through the executable packet dataplane (DESIGN.md §9).

Runs the same federated task twice — over the idealized in-memory
transport and over the packet-level switch dataplane — then degrades the
network: packet loss with retransmission, partial client participation,
stragglers bounded by the vote-quorum deadline, and a two-level
leaf -> root switch hierarchy.  Lossless full participation is bit-exact
with the in-memory engine, so every accuracy difference you see below is
*caused by the network*, not by simulator drift.

  PYTHONPATH=src python examples/fl_lossy_network.py [--rounds 30]
      [--clients 10] [--loss 0.05] [--participation 0.5] [--leaves 2]
"""

import argparse

from repro.core.fediac import FediACConfig
from repro.data import classification, partition_dirichlet
from repro.netsim import NetConfig
from repro.training import FLConfig, run_federated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--loss", type=float, default=0.05)
    ap.add_argument("--participation", type=float, default=0.5)
    ap.add_argument("--leaves", type=int, default=2)
    args = ap.parse_args()

    data = classification(n=6000, dim=32, n_classes=10, seed=0)
    train, test = data.test_split(0.2)
    clients = partition_dirichlet(train, args.clients, beta=0.5, seed=0)

    scenarios = {
        "memory (analytic)": dict(transport="memory", net=None),
        "packet lossless": dict(transport="packet", net=NetConfig()),
        f"packet loss={args.loss:g}": dict(
            transport="packet", net=NetConfig(loss=args.loss, seed=1)),
        f"packet part={args.participation:g}": dict(
            transport="packet",
            net=NetConfig(participation=args.participation, seed=1)),
        "packet stragglers+quorum": dict(
            transport="packet",
            net=NetConfig(straggler_frac=0.3, straggler_slowdown=20.0,
                          vote_deadline_s=0.5, seed=1)),
        f"packet {args.leaves}-leaf tree": dict(
            transport="packet", net=NetConfig(n_leaves=args.leaves)),
    }
    print(f"{'scenario':26s} {'final acc':>9s} {'wall clock':>11s} {'traffic':>10s}")
    for name, spec in scenarios.items():
        cfg = FLConfig(n_clients=args.clients, rounds=args.rounds,
                       local_steps=3, aggregator="fediac",
                       agg_kwargs={"cfg": FediACConfig(a=2, bits=12)},
                       seed=0, **spec)
        h = run_federated(clients, test, cfg)
        print(f"{name:26s} {h.acc[-1]:9.4f} {h.wall_clock[-1]:10.2f}s "
              f"{h.traffic_mb[-1]:9.2f}MB")


if __name__ == "__main__":
    main()
