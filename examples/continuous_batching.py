"""Continuous-batching serving: ragged requests share one decode batch.

Five requests with different prompt/generation lengths stream through two
decode slots — each engine tick advances every active slot by one token at
its own position (prefill and generation interleaved in the same batch),
finished slots recycle to queued requests.

  PYTHONPATH=src python examples/continuous_batching.py [--arch mamba2-130m]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models.model import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=n).tolist(),
                    max_new=m)
            for i, (n, m) in enumerate([(6, 8), (12, 4), (3, 10), (8, 6), (5, 5)])]

    engine = ServingEngine(cfg, params, max_batch=args.slots, cache_len=64)
    t0 = time.time()
    engine.run(list(reqs))
    dt = time.time() - t0
    total = sum(len(r.prompt) + len(r.out) for r in reqs)
    print(f"{len(reqs)} requests through {args.slots} slots: "
          f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s)\n")
    for r in reqs:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
