"""FediAC under Byzantine attack, with and without defenses (DESIGN.md §18).

Runs the same non-IID federated task five ways on one compiled robust
round program — the attack and defense knobs are traced per-cell
scalars, so the whole grid shares a single ``jit(vmap)`` fleet batch:

* **clean** — no adversary (the control; bit-identical to the plain
  packet dataplane at zero knobs);
* **stuffing** — 25% persistent Byzantine clients vote for extra chunks
  beyond their honest top-k, colluders steering a shared target set;
* **poisoning** — the same cohort transmits ``-8x`` scaled sign-flipped
  updates, inflating the shared quantization scale f through the global
  ``max|u|``;
* **full attack, undefended** — both at once (collapses to random);
* **full attack, defended** — per-client vote budgets, int-domain
  clipping, the trimmed-mean slot close, and the reputation/quarantine
  layer (recovers >= 0.9x the clean accuracy at the default 10 rounds).

The per-round robust counters (``stuffed_votes``, ``budget_rejected``,
``quarantined``, ...) surface through the §15 stats dict; the tracked
``BENCH_robust.json`` gates the same cells in CI.

  PYTHONPATH=src python examples/fl_byzantine.py [--rounds 10]
      [--byzantine 0.25] [--poison -8.0] [--sequential]
"""

import argparse
import time
from dataclasses import replace

from repro.sweep import run_sweep
from repro.sweep.grids import attack_grid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--byzantine", type=float, default=0.25,
                    help="Byzantine client fraction for the attack cells")
    ap.add_argument("--poison", type=float, default=-8.0,
                    help="poison scale (-1 is a pure sign flip)")
    ap.add_argument("--sequential", action="store_true",
                    help="force the per-cell run_federated path (the "
                         "fleet's bit-identity oracle) for comparison")
    args = ap.parse_args()

    specs = []
    for s in attack_grid():
        kw = {"rounds": args.rounds}
        if s.byzantine_frac > 0:
            kw["byzantine_frac"] = args.byzantine
            kw["collusion_frac"] = min(s.collusion_frac, args.byzantine)
        if s.poison_scale != 1.0:
            kw["poison_scale"] = args.poison
        specs.append(replace(s, **kw))
    assert len({s.batch_signature() for s in specs}) == 1, \
        "the attack x defense grid must share one fleet program"

    t0 = time.perf_counter()
    result = run_sweep(specs, (0,), sequential=args.sequential)
    dt = time.perf_counter() - t0

    mode = "sequential" if args.sequential else "fleet"
    print(f"{len(specs)} scenarios in {dt:.1f}s ({mode}), "
          f"byzantine={args.byzantine:g}, poison={args.poison:g}")
    by_name = {cr.spec.name: cr.history for cr in result}
    clean = by_name["attack-clean"].acc[-1]
    print(f"{'scenario':22s} {'final acc':>9s} {'vs clean':>9s}")
    for cr in result:
        h = cr.history
        print(f"{cr.spec.name:22s} {h.acc[-1]:9.4f} "
              f"{h.acc[-1] / max(clean, 1e-9):8.2f}x")
    defended = by_name["attack-full-defended"].acc[-1]
    undefended = by_name["attack-full"].acc[-1]
    print(f"\ndefense recovered {defended / max(clean, 1e-9):.0%} of clean "
          f"accuracy (undefended: {undefended / max(clean, 1e-9):.0%})")


if __name__ == "__main__":
    main()
