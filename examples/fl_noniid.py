"""End-to-end federated training driver (the paper's experiment, Sec. V).

20 clients with Dirichlet(0.5) non-IID data train a classifier for a few
hundred rounds through the in-network switch simulator; FediAC is compared
against SwitchML and dense FedAvg on accuracy, wall-clock (M/G/1 queuing
model of the PS) and traffic.

  PYTHONPATH=src python examples/fl_noniid.py [--rounds 150] [--low-perf]
"""

import argparse

from repro.core.fediac import FediACConfig
from repro.data import classification, partition_dirichlet
from repro.switch import SwitchProfile
from repro.training import FLConfig, run_federated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--low-perf", action="store_true",
                    help="use the low-performance switch profile")
    args = ap.parse_args()

    data = classification(n=12_000, dim=48, n_classes=10, seed=0)
    train, test = data.test_split(0.2)
    clients = partition_dirichlet(train, args.clients, beta=args.beta, seed=0)
    switch = SwitchProfile.low() if args.low_perf else SwitchProfile.high()

    algos = {
        "fediac": dict(aggregator="fediac",
                       agg_kwargs={"cfg": FediACConfig(a=3, bits=12)}),
        "switchml": dict(aggregator="switchml", agg_kwargs={"bits": 12}),
        "fedavg": dict(aggregator="fedavg", agg_kwargs={}),
    }
    print(f"{'algo':10s} {'final acc':>9s} {'wall clock':>11s} {'traffic':>10s}")
    for name, spec in algos.items():
        cfg = FLConfig(n_clients=args.clients, rounds=args.rounds, local_steps=5,
                       switch=switch, local_train_s=0.1, seed=0, **spec)
        h = run_federated(clients, test, cfg)
        print(f"{name:10s} {h.acc[-1]:9.4f} {h.wall_clock[-1]:10.1f}s "
              f"{h.traffic_mb[-1]:9.1f}MB")


if __name__ == "__main__":
    main()
