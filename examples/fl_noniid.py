"""End-to-end federated training driver (the paper's experiment, Sec. V).

20 clients with Dirichlet(0.5) non-IID data train a classifier for a few
hundred rounds through the in-network switch simulator; FediAC is compared
against SwitchML and dense FedAvg on accuracy, wall-clock (M/G/1 queuing
model of the PS) and traffic.

The three algorithms run through the sweep engine (``repro.sweep``): each
is one :class:`ScenarioSpec` cell, and same-shape cells batch through one
vmapped round program instead of re-compiling per algorithm.  Pass
``--seeds 3`` to sweep seeds too (mean +- spread across the fleet axis).

  PYTHONPATH=src python examples/fl_noniid.py [--rounds 150] [--low-perf]
"""

import argparse

import numpy as np

from repro.sweep import ScenarioSpec, run_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds to sweep (fleet axis)")
    ap.add_argument("--low-perf", action="store_true",
                    help="use the low-performance switch profile")
    args = ap.parse_args()

    task = dict(n_clients=args.clients, rounds=args.rounds, local_steps=5,
                beta=args.beta, dist="noniid", data_n=12_000,
                switch="low" if args.low_perf else "high",
                local_train_s=0.1)
    specs = [
        ScenarioSpec(name="fediac", algorithm="fediac", a=3, bits=12, **task),
        ScenarioSpec(name="switchml", algorithm="switchml",
                     agg_overrides=(("bits", 12),), **task),
        ScenarioSpec(name="fedavg", algorithm="fedavg", **task),
    ]
    result = run_sweep(specs, tuple(range(args.seeds)))

    print(f"{'algo':10s} {'final acc':>9s} {'wall clock':>11s} {'traffic':>10s}")
    for spec in specs:
        accs = [c.history.acc[-1] for c in result if c.spec.name == spec.name]
        cell = next(c for c in result
                    if c.spec.name == spec.name and c.seed == 0)
        h = cell.history
        spread = (f" (+-{np.std(accs):.4f} over {len(accs)} seeds)"
                  if len(accs) > 1 else "")
        print(f"{spec.name:10s} {h.acc[-1]:9.4f} {h.wall_clock[-1]:10.1f}s "
              f"{h.traffic_mb[-1]:9.1f}MB{spread}")


if __name__ == "__main__":
    main()
