"""Batched serving: prefill-by-steps + greedy decode with per-arch caches.

Exercises the three cache families of the zoo: dense KV (qwen3), SSD
recurrent state (mamba2), and the hybrid attn+SSM cache with sliding-window
ring buffer (hymba) — the same machinery the long_500k dry-run shape lowers.

  PYTHONPATH=src python examples/serving_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.model import decode_step, init_caches, init_params


def serve(arch: str, ring: bool = False, cache_len: int = 64):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, prompt_len, gen = 4, 24, 16
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)), jnp.int32)
    caches = init_caches(cfg, b, cache_len)
    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos, ring=ring))

    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, caches = step(params, prompt[:, i:i + 1], caches, jnp.int32(i))
    toks = []
    for i in range(gen):
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        toks.append(nxt)
        logits, caches = step(params, nxt, caches, jnp.int32(prompt_len + i))
    dt = time.time() - t0
    total = b * (prompt_len + gen)
    print(f"{arch:14s} ring={str(ring):5s} {total / dt:8.1f} tok/s  "
          f"sample: {np.asarray(jnp.concatenate(toks, 1))[0][:8]}")


if __name__ == "__main__":
    serve("qwen3_0p6b")
    serve("mamba2_130m")
    serve("hymba_1p5b", ring=True, cache_len=32)  # SWA ring buffer
